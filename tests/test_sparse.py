"""Sparse submodel update plane: representation, parity with the dense path,
kernels, compression, and the end-to-end sparse trainer/round-step modes.

Deliberately hypothesis-free (seeded sweeps) so the sparse plane keeps test
coverage even where hypothesis is not installed.
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FedConfig, get_smoke_config
from repro.core.aggregate import HeatSpec, correct_update_tree
from repro.data import make_amazon_like, make_movielens_like
from repro.federated import (FederatedTrainer, count_sub_ids, derive_sub_ids,
                             make_round_step, pow2_capacity, round_capacity)
from repro.kernels import ops, ref
from repro.models import build_model
from repro.models.recsys import (lr_logits, lr_loss, lstm_loss, make_lr_params,
                                 make_lstm_params)
from repro.sharding.logical import unbox
from repro.sparse import (RowSparse, aggregate_rowsparse, apply_rowsparse,
                          batch_union_ids, dequantize_rows, encode_delta_tree,
                          quantize_rows_int8, sparse_cohort_aggregate,
                          submodel_value_and_grad, topk_rows, tree_wire_bytes,
                          unique_ids_padded)


def _random_cohort(rng, k, v, d, max_rows):
    """Per-client supports incl. empty-ish clients; returns ids, dense deltas."""
    ids = np.full((k, max_rows), -1, np.int32)
    dense = np.zeros((k, v, d), np.float32)
    for i in range(k):
        n = int(rng.integers(1, max_rows + 1))
        sup = np.sort(rng.choice(v, size=n, replace=False))
        ids[i, :n] = sup
        dense[i, sup] = rng.normal(size=(n, d))
    return ids, dense


# ---------------------------------------------------------------------------
# representation
# ---------------------------------------------------------------------------


def test_rowsparse_roundtrip_and_jit(rng):
    v, d = 24, 3
    ids = jnp.asarray([1, 5, 7, -1, -1], jnp.int32)
    dense = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    rs = RowSparse.from_dense(dense, ids)
    want = np.zeros((v, d), np.float32)
    for i in (1, 5, 7):
        want[i] = np.asarray(dense)[i]
    np.testing.assert_allclose(np.asarray(rs.to_dense()), want)
    # flows through jit/vmap as a pytree, aux data intact
    out = jax.jit(lambda r: r.scale(3.0))(rs)
    assert out.num_rows == v
    np.testing.assert_allclose(np.asarray(out.to_dense()), 3 * want, rtol=1e-6)
    stacked = jax.vmap(RowSparse.from_dense, in_axes=(None, 0))(
        dense, jnp.stack([ids, ids]))
    assert stacked.ids.shape == (2, 5)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_unique_ids_padded_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    raw = rng.integers(-1, 40, size=64).astype(np.int32)
    cap = 48
    out = np.asarray(unique_ids_padded(jnp.asarray(raw), cap))
    want = np.unique(raw[raw >= 0])
    np.testing.assert_array_equal(out[: len(want)], want)
    assert np.all(out[len(want):] == -1)
    # capacity overflow drops the tail deterministically
    tight = np.asarray(unique_ids_padded(jnp.asarray(raw), 4))
    np.testing.assert_array_equal(tight, want[:4])


# ---------------------------------------------------------------------------
# sparse/dense aggregation parity (the ISSUE's property test)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("union_backend", ["bitmap", "sort"])
def test_sparse_aggregation_matches_dense_correction(seed, union_backend):
    """Sparse encode + segment-sum + fused N/n_m == dense mean + correct,
    including cold rows (n_m = 0) and -1 padding ids."""
    rng = np.random.default_rng(seed)
    k, v, d = 5, 37, 3
    ids_np, dense = _random_cohort(rng, k, v, d, max_rows=11)
    heat = np.zeros(v, np.float64)
    for i in range(k):
        heat[ids_np[i][ids_np[i] >= 0]] += 1
    assert (heat == 0).any(), "want genuinely cold rows in this fixture"
    total = 20.0
    spec = HeatSpec({"emb": ("vocab", 0), "b": None})
    counts = {"vocab": jnp.asarray(heat, jnp.float32)}
    delta = {"emb": jnp.asarray(dense),
             "b": jnp.asarray(rng.normal(size=(k, 4)), jnp.float32)}

    enc = encode_delta_tree(delta, spec, jnp.asarray(ids_np))
    stacked = enc["emb"]
    agg = aggregate_rowsparse(stacked, counts["vocab"], total, 1.0 / k,
                              union_backend=union_backend)
    got = np.asarray(agg.to_dense())

    dense_mean = jax.tree.map(lambda x: x.mean(axis=0), delta)
    want = np.asarray(correct_update_tree(dense_mean, spec, counts, total)["emb"])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    # tree-level helper agrees too, and passes dense leaves through as means
    tree_agg = sparse_cohort_aggregate(enc, spec, counts, total, k)
    np.testing.assert_allclose(np.asarray(tree_agg["emb"].to_dense()), want,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(tree_agg["b"]),
                               np.asarray(dense_mean["b"]), rtol=1e-6)


def test_sparse_cohort_aggregate_corrects_trailing_axis_leaves(rng):
    """A vocab-spaced dense leaf (e.g. an LM head, vocab on axis 1) must get
    the same broadcast correction the dense server applies."""
    k, v, d = 3, 12, 4
    heat = np.array([0, 1, 2, 3, 0, 4, 1, 2, 3, 4, 1, 2], np.float64)
    spec = HeatSpec({"head": ("vocab", 1)})
    counts = {"vocab": jnp.asarray(heat, jnp.float32)}
    delta = {"head": jnp.asarray(rng.normal(size=(k, d, v)), jnp.float32)}
    agg = sparse_cohort_aggregate(delta, spec, counts, total=8.0,
                                  num_clients_in_cohort=k)
    dense_mean = jax.tree.map(lambda x: x.mean(axis=0), delta)
    want = correct_update_tree(dense_mean, spec, counts, 8.0)["head"]
    np.testing.assert_allclose(np.asarray(agg["head"]), np.asarray(want),
                               rtol=1e-5, atol=1e-7)


def test_apply_rowsparse_matches_dense_add(rng):
    v, d = 16, 2
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    ids = jnp.asarray([0, 3, 9, -1], jnp.int32)
    rows = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
    rows = rows * (np.asarray(ids) >= 0)[:, None]
    rs = RowSparse(ids, rows, v)
    got = apply_rowsparse(table, rs, 0.5)
    want = table + 0.5 * rs.to_dense()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# generalized Pallas kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,d,v,v_blk,t_blk", [
    (256, 8, 64, 16, 64),
    (500, 16, 96, 32, 128),      # non-multiple T exercises row padding
    (300, 8, 101, 32, 128),      # odd vocab exercises vocab padding
])
def test_rowsparse_scatter_kernel_vs_ref(rng, t, d, v, v_blk, t_blk):
    ids = jnp.asarray(rng.integers(-1, v, t), jnp.int32)
    rows = jnp.asarray(rng.normal(0, 1, (t, d)), jnp.float32)
    heat = jnp.asarray(rng.integers(0, 7, v), jnp.float32)
    out = ops.rowsparse_scatter(ids, rows, heat, 64.0, v, scale=0.125,
                                v_blk=v_blk, t_blk=t_blk)
    want = ref.rowsparse_scatter_ref(ids, rows, heat, 64.0, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_kernel_matches_sparse_aggregate(rng):
    """The Pallas dense-output path and the jnp union path agree."""
    k, v, d = 4, 64, 8
    ids_np, dense = _random_cohort(rng, k, v, d, max_rows=12)
    heat = jnp.asarray(np.maximum(rng.integers(0, 4, v), 0), jnp.float32)
    stacked = jax.vmap(RowSparse.from_dense)(jnp.asarray(dense),
                                             jnp.asarray(ids_np))
    from repro.sparse import aggregate_rowsparse_dense
    got_pl = aggregate_rowsparse_dense(stacked, heat, 32.0, scale=0.25,
                                       backend="pallas")
    got_jnp = aggregate_rowsparse_dense(stacked, heat, 32.0, scale=0.25,
                                        backend="jnp")
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(got_jnp),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused union_segsum kernel (the sparse server engine)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("v,v_blk,t_blk", [
    (64, 16, 32),
    (101, 32, 64),       # V not a multiple of the block
    (37, 8, 16),
])
def test_union_segsum_matches_jnp_backends(seed, v, v_blk, t_blk):
    """Randomized cohorts (duplicate ids across clients by construction):
    the fused kernel's RowSparse output equals both jnp backends'."""
    from repro.kernels.union_segsum import union_segsum
    rng = np.random.default_rng(seed)
    k, d = 4, 5
    ids_np, dense = _random_cohort(rng, k, v, d, max_rows=max(v // 3, 4))
    heat = np.zeros(v, np.float64)
    for i in range(k):
        heat[ids_np[i][ids_np[i] >= 0]] += 1
    stacked = jax.vmap(RowSparse.from_dense)(jnp.asarray(dense),
                                             jnp.asarray(ids_np))
    total, scale = 24.0, 0.25
    heat_j = jnp.asarray(heat, jnp.float32)
    want = {b: aggregate_rowsparse(stacked, heat_j, total, scale,
                                   union_backend=b)
            for b in ("bitmap", "sort")}
    got = aggregate_rowsparse(stacked, heat_j, total, scale,
                              union_backend="pallas")
    for b, w in want.items():
        np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(w.ids))
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   np.asarray(w.to_dense()),
                                   rtol=1e-5, atol=1e-5, err_msg=b)
    # direct kernel call with explicit small blocks agrees too
    u_ids, u_rows = union_segsum(stacked.ids, stacked.rows, heat_j, total,
                                 got.capacity, v, scale=scale,
                                 v_blk=v_blk, t_blk=t_blk)
    np.testing.assert_array_equal(np.asarray(u_ids), np.asarray(got.ids))
    np.testing.assert_allclose(np.asarray(u_rows), np.asarray(got.rows),
                               rtol=1e-5, atol=1e-5)


def test_union_segsum_all_pad_clients_and_exact_cap(rng):
    """All-pad clients contribute nothing; cap == union size exactly fills
    every slot; cap < union drops the largest ids (sort-backend semantics)."""
    v, d = 40, 3
    ids = np.array([[3, 7, 11, -1], [-1, -1, -1, -1], [7, 20, -1, -1]],
                   np.int32)
    rows = rng.normal(size=(3, 4, d)).astype(np.float32)
    rows[ids < 0] = 0
    heat = jnp.asarray(rng.integers(1, 5, v), jnp.float32)
    stacked = RowSparse(jnp.asarray(ids), jnp.asarray(rows), v)
    union = {3, 7, 11, 20}
    for cap in (len(union), len(union) - 1, len(union) + 3):
        got = aggregate_rowsparse(stacked, heat, 10.0, 0.5,
                                  union_capacity=cap, union_backend="pallas")
        want = aggregate_rowsparse(stacked, heat, 10.0, 0.5,
                                   union_capacity=cap, union_backend="sort")
        np.testing.assert_array_equal(np.asarray(got.ids),
                                      np.asarray(want.ids))
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   np.asarray(want.to_dense()),
                                   rtol=1e-5, atol=1e-6)
    lone = aggregate_rowsparse(
        RowSparse(jnp.asarray(ids[1:2]), jnp.asarray(rows[1:2]), v), heat,
        10.0, 1.0, union_backend="pallas")
    assert int((lone.ids >= 0).sum()) == 0
    np.testing.assert_array_equal(np.asarray(lone.to_dense()), 0)


def test_union_backend_auto_selection(monkeypatch):
    """'auto' resolves to a jnp backend off-TPU and to the fused kernel on
    TPU whenever the union fits VMEM (interpret vs compiled selection)."""
    import importlib
    hs_mod = importlib.import_module("repro.kernels.heat_scatter")
    from repro.sparse import aggregate as agg_mod
    assert agg_mod._resolve_backend("auto", 1000, 64, 8, 256) in ("bitmap",
                                                                  "sort")
    assert agg_mod._resolve_backend("pallas", 1000, 64, 8, 256) == "pallas"
    monkeypatch.setattr(hs_mod, "on_tpu", lambda: True)
    assert agg_mod._resolve_backend("auto", 1000, 64, 8, 256) == "pallas"
    # beyond the VMEM budget auto falls back to the jnp backends
    assert agg_mod._resolve_backend(
        "auto", 1 << 23, 1 << 22, 64, 1 << 22) == "sort"
    # huge feature spaces never auto-select the kernel (grid scales with V),
    # even when the union itself would fit VMEM
    assert agg_mod._resolve_backend(
        "auto", (1 << 22) + 1, 64, 8, 256) == "sort"
    # the kernel wrapper keys interpret mode off the same runtime check
    us_mod = importlib.import_module("repro.kernels.union_segsum")
    assert us_mod.fits_vmem(64, 8) and not us_mod.fits_vmem(1 << 22, 64)


def test_fits_vmem_uses_actual_block_sizes():
    """Regression: the budget guard mirrors the kernel's own block
    adjustments (pow2-shrunk ``v_blk``, ``t_blk`` clamped to the element
    count), so a small cohort/feature space can fit the budget where the
    default-block estimate would refuse it."""
    from repro.kernels.union_segsum import _pick_blk, fits_vmem
    cap, d = 1024, 1024
    assert not fits_vmem(cap, d)                      # default 512-blocks
    assert fits_vmem(cap, d, num_rows=64, t=64)       # kernel-shrunk blocks
    # the adjustment matches the kernel's: _pick_blk on v, min-clamp on t
    assert _pick_blk(64, 512) == 64


def test_union_segsum_grid_dims_sequential(monkeypatch):
    """Regression: both grid dims of union_segsum are order-dependent (the
    SMEM union-offset carry threads across vocab blocks), so the compiled
    path must never declare a 'parallel' dim — reusing heat_scatter's
    vocab-parallel default would corrupt the union on Megacore TPUs."""
    import importlib
    hs_mod = importlib.import_module("repro.kernels.heat_scatter")
    us_mod = importlib.import_module("repro.kernels.union_segsum")
    assert us_mod._DIM_SEMANTICS == ("arbitrary", "arbitrary")
    cp = hs_mod._tpu_compiler_params(semantics=us_mod._DIM_SEMANTICS)
    if cp is not None:
        assert "parallel" not in tuple(cp.dimension_semantics)
    # heat_scatter's own default (independent vocab blocks) is unchanged
    cp_hs = hs_mod._tpu_compiler_params()
    if cp_hs is not None:
        assert tuple(cp_hs.dimension_semantics) == ("parallel", "arbitrary")

    # and the compiled path actually requests those semantics: capture what
    # union_segsum hands to _tpu_compiler_params on interpret=False (the
    # kernel itself still executes via the interpreter on CPU)
    seen = {}

    def fake_params(semantics=("parallel", "arbitrary")):
        seen["semantics"] = tuple(semantics)
        return None

    real_call = us_mod.pl.pallas_call

    def interpreted_call(*args, **kw):
        seen["interpret"] = kw.get("interpret")
        kw["interpret"] = True
        return real_call(*args, **kw)

    monkeypatch.setattr(us_mod, "_tpu_compiler_params", fake_params)
    monkeypatch.setattr(us_mod.pl, "pallas_call", interpreted_call)
    ids = jnp.asarray([[0, 2, -1]], jnp.int32)
    rows = jnp.ones((1, 3, 4), jnp.float32)
    u, _ = us_mod.union_segsum(ids, rows, None, 4.0, 4, 8, interpret=False)
    assert seen["interpret"] is False
    assert seen["semantics"] == us_mod._DIM_SEMANTICS
    assert sorted(np.asarray(u)[np.asarray(u) >= 0].tolist()) == [0, 2]


def test_union_segsum_scalar_params_do_not_retrace(rng):
    """total/scale are traced scalar operands of the jitted kernel wrapper:
    sweeping them hits one compiled program (no per-value retrace) while
    still scaling the output."""
    from repro.kernels import ops
    v, d = 32, 4
    ids = jnp.asarray([[1, 5, 9, -1]], jnp.int32)
    rows = jnp.asarray(rng.normal(size=(1, 4, d)), jnp.float32)
    heat = jnp.ones((v,), jnp.float32)
    before = ops.union_segsum._cache_size()
    outs = [ops.union_segsum(ids, rows, heat, total, 8, v, scale=scale)
            for total, scale in ((2.0, 1.0), (4.0, 1.0), (4.0, 0.5))]
    assert ops.union_segsum._cache_size() - before <= 1
    r0, r1, r2 = (np.asarray(r) for _, r in outs)
    np.testing.assert_allclose(r1, 2 * r0, rtol=1e-6)
    np.testing.assert_allclose(r2, r0, rtol=1e-6)


# ---------------------------------------------------------------------------
# jitted sub-id derivation (server engine preprocessing)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_derive_sub_ids_matches_numpy_path(seed):
    """The jitted bitmap-rank derivation reproduces the old host-side
    per-client np.unique loop exactly (ids, padding, and counts)."""
    rng = np.random.default_rng(seed)
    k, m, v = 6, 40, 57
    feats = rng.integers(-1, v, (k, m)).astype(np.int32)
    feats[2] = -1                                    # an all-pad client
    counts = np.asarray(count_sub_ids(jnp.asarray(feats), v))
    capacity = pow2_capacity(int(counts.max()))
    got = np.asarray(derive_sub_ids(jnp.asarray(feats), v, capacity))
    for c in range(k):
        u = np.unique(feats[c])
        u = u[u >= 0]
        assert counts[c] == len(u)
        np.testing.assert_array_equal(got[c, : len(u)], u)
        assert np.all(got[c, len(u):] == -1)


def test_pow2_capacity_invariant():
    """Regression: capacities are pure powers of two (>= 8) so the jitted
    round step compiles O(log V) variants — the old trainer clamped the
    bucket to a non-pow2 table size, breaking the ladder."""
    assert pow2_capacity(0) == 8 and pow2_capacity(8) == 8
    for n in (3, 9, 70, 100, 1000):
        cap = pow2_capacity(n)
        assert cap >= max(n, 8) and (cap & (cap - 1)) == 0
    # the broken variant: min(pow2, V) with V=100 gave 100 for counts > 64
    assert pow2_capacity(70) == 128


def test_round_capacity_clamped_to_vocab():
    """Regression: rounding the union capacity up to a multiple of 8 must
    never allocate slots past the feature table (e.g. V=50257 -> 50264)."""
    assert round_capacity(50257, 10 ** 9) == 50257
    assert round_capacity(101, 1000) == 101
    cap = round_capacity(101, 50)
    assert cap == 56 and cap % 8 == 0          # rounding still applies
    assert round_capacity(8, 3) == 8


def test_simulation_sparse_mode_odd_vocab_runs():
    """End-to-end regression companion: a vocab that is not a multiple of 8
    with a batch large enough to trigger the clamp still runs exactly."""
    from repro.models.recsys import lstm_loss, make_lstm_params
    v = 41
    params = make_lstm_params(v, emb_dim=6, hidden=8, layers=1,
                              rng=jax.random.PRNGKey(1))
    fed = FedConfig(num_clients=16, clients_per_round=4, lr=0.1,
                    algorithm="fedsubavg")
    step = make_round_step(lstm_loss, params, fed, mode="sparse")
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, v, (8, 16)), jnp.int32),
             "label": jnp.asarray(rng.integers(0, 2, 8), jnp.int32),
             "heat_vocab": jnp.full((v,), 4.0)}
    new_params, metrics = jax.jit(step)(params, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["density"]) <= 1.0


# ---------------------------------------------------------------------------
# gather-before-backward encoder
# ---------------------------------------------------------------------------


def test_submodel_grads_match_dense_grads_lr(rng):
    v = 50
    params = make_lr_params(v, rng=jax.random.PRNGKey(0))
    params["w"].value = jnp.asarray(rng.normal(size=(v, 1)), jnp.float32)
    batch = {"features": jnp.asarray(rng.integers(-1, v, (6, 5)), jnp.int32),
             "label": jnp.asarray(rng.integers(0, 2, 6), jnp.int32)}
    ids = batch_union_ids(batch, ("features",), 32)
    loss_s, grads = submodel_value_and_grad(lr_loss, params, batch,
                                            ("w",), ("features",), ids)
    loss_d, dense_grads = jax.value_and_grad(lr_loss)(params, batch)
    np.testing.assert_allclose(float(loss_s), float(loss_d), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(grads["w"].to_dense()),
                               np.asarray(unbox(dense_grads)["w"]),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(unbox(grads["b"])),
                               np.asarray(unbox(dense_grads)["b"]), rtol=1e-6)


def test_submodel_grads_match_dense_grads_lstm(rng):
    v = 40
    params = make_lstm_params(v, emb_dim=6, hidden=8, layers=1,
                              rng=jax.random.PRNGKey(1))
    batch = {"tokens": jnp.asarray(rng.integers(-1, v, (4, 7)), jnp.int32),
             "label": jnp.asarray(rng.integers(0, 2, 4), jnp.int32)}
    ids = batch_union_ids(batch, ("tokens",), 32)
    loss_s, grads = submodel_value_and_grad(lstm_loss, params, batch,
                                            ("embedding",), ("tokens",), ids)
    loss_d, dense_grads = jax.value_and_grad(lstm_loss)(params, batch)
    np.testing.assert_allclose(float(loss_s), float(loss_d), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(grads["embedding"].to_dense()),
                               np.asarray(unbox(dense_grads)["embedding"]),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_topk_rows_keeps_largest(rng):
    v, r, d = 30, 8, 2
    ids = jnp.asarray([2, 4, 6, 8, 10, -1, -1, -1], jnp.int32)
    rows = np.zeros((r, d), np.float32)
    rows[:5] = rng.normal(size=(5, d))
    rs = RowSparse(ids, jnp.asarray(rows), v)
    out = topk_rows(rs, 3)
    norms = (rows ** 2).sum(-1)[:5]
    want_ids = np.sort(np.asarray(ids)[:5][np.argsort(norms)[-3:]])
    np.testing.assert_array_equal(np.asarray(out.ids), want_ids)
    # fewer valid rows than k -> padding survives as padding
    out2 = topk_rows(RowSparse(ids, jnp.asarray(rows), v), 7)
    assert int((out2.ids >= 0).sum()) == 5


def test_int8_stochastic_rounding_unbiased(rng):
    v, r, d = 20, 6, 4
    ids = jnp.asarray([1, 3, 5, 7, 9, -1], jnp.int32)
    rows = jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
    rows = rows * (np.asarray(ids) >= 0)[:, None]
    rs = RowSparse(ids, rows, v)
    keys = jax.random.split(jax.random.PRNGKey(0), 400)
    dq = jax.vmap(lambda k: dequantize_rows(quantize_rows_int8(rs, k)).rows)(keys)
    mean = np.asarray(dq.mean(axis=0))
    scales = np.abs(np.asarray(rows)).max(-1, keepdims=True) / 127.0
    # unbiased: the Monte-Carlo mean approaches the true rows
    np.testing.assert_allclose(mean, np.asarray(rows),
                               atol=3 * float(scales.max()) / np.sqrt(400) * 4)
    # single-shot error bounded by one quantisation step
    one = np.asarray(dequantize_rows(quantize_rows_int8(rs, keys[0])).rows)
    assert np.all(np.abs(one - np.asarray(rows)) <= np.maximum(scales, 1e-6) + 1e-6)


# ---------------------------------------------------------------------------
# end-to-end: FederatedTrainer sparse mode == dense mode
# ---------------------------------------------------------------------------


def _make_trainer(ds, sparse, alg="fedsubavg", **kw):
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=6,
                    local_iters=3, local_batch=4, lr=0.5, algorithm=alg,
                    sparse=sparse, **kw)
    mk = functools.partial(make_lr_params, ds.num_features)
    return FederatedTrainer(
        ds, mk, lr_loss, cfg,
        predict_fn=lambda p, t: lr_logits(p, jnp.asarray(t["features"])),
        metric="auc")


@pytest.fixture(scope="module")
def small_ds():
    return make_movielens_like(num_clients=40, num_items=40, mean_samples=15)


def test_trainer_sparse_matches_dense(small_ds):
    td = _make_trainer(small_ds, sparse=False)
    ts = _make_trainer(small_ds, sparse=True)
    losses_d = [td.run_round() for _ in range(8)]
    losses_s = [ts.run_round() for _ in range(8)]
    np.testing.assert_allclose(losses_s, losses_d, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(unbox(td.state.params)),
                    jax.tree.leaves(unbox(ts.state.params))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_trainer_comm_accounting(small_ds):
    ts = _make_trainer(small_ds, sparse=True)
    ts.run(4, eval_every=4)
    assert len(ts.comm_log) == 4
    s = ts.comm_summary()
    assert 0 < s["mean_density"] < 1
    assert s["bytes_up_sparse"] < s["bytes_up_dense"]
    assert s["up_ratio"] > 1
    rec = ts.history[-1]
    assert rec.bytes_up > 0 and rec.density == pytest.approx(s["mean_density"])


def test_trainer_sparse_din_includes_targets():
    """DIN deltas are supported on hist AND target ids; parity must hold."""
    ds = make_amazon_like(num_clients=30, num_items=60, mean_samples=12)
    from repro.models.recsys import din_logits, din_loss, make_din_params
    def mk(sparse):
        cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=5,
                        local_iters=2, local_batch=4, lr=0.3,
                        algorithm="fedsubavg", sparse=sparse)
        return FederatedTrainer(
            ds, functools.partial(make_din_params, ds.num_features), din_loss,
            cfg, predict_fn=lambda p, t: din_logits(p, jnp.asarray(t["hist"]),
                                                    jnp.asarray(t["target"])))
    ld = [mk(False).run_round() for _ in range(1)]
    td, ts = mk(False), mk(True)
    losses_d = [td.run_round() for _ in range(4)]
    losses_s = [ts.run_round() for _ in range(4)]
    np.testing.assert_allclose(losses_s, losses_d, rtol=1e-5, atol=1e-6)


def test_trainer_run_rounds_matches_run_round(small_ds):
    """The in-jit multi-round engine (one lax.scan) reproduces the per-round
    loop: same RNG stream, same losses, same parameters, same comm log."""
    tr_loop = _make_trainer(small_ds, sparse=True)
    tr_scan = _make_trainer(small_ds, sparse=True)
    losses_loop = [tr_loop.run_round() for _ in range(6)]
    losses_scan = tr_scan.run_rounds(6)
    np.testing.assert_allclose(losses_scan, losses_loop, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(unbox(tr_loop.state.params)),
                    jax.tree.leaves(unbox(tr_scan.state.params))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    assert len(tr_scan.comm_log) == len(tr_loop.comm_log) == 6
    for cl, cs in zip(tr_loop.comm_log, tr_scan.comm_log):
        assert cs.bytes_up_sparse == pytest.approx(cl.bytes_up_sparse)
    # run(engine=True) drives the same engine and surfaces wall time
    tr_eng = _make_trainer(small_ds, sparse=True)
    tr_eng.run(4, eval_every=2, engine=True)
    assert tr_eng.history[-1].round == 4
    assert tr_eng.history[-1].wall_time > 0
    # engine composes with the compression variants
    tr_c = _make_trainer(small_ds, sparse=True, sparse_topk=6, sparse_int8=True)
    assert np.all(np.isfinite(tr_c.run_rounds(3)))


def test_trainer_run_rounds_dense_fallback(small_ds):
    """Non-sparse configs fall back to the per-round loop transparently."""
    tr = _make_trainer(small_ds, sparse=False)
    losses = tr.run_rounds(2)
    assert len(losses) == 2 and np.all(np.isfinite(losses))
    assert tr._rounds_run == 2


def test_trainer_sparse_compression_variants_run(small_ds):
    for kw in (dict(sparse_topk=6), dict(sparse_int8=True)):
        tr = _make_trainer(small_ds, sparse=True, **kw)
        losses = [tr.run_round() for _ in range(3)]
        assert np.all(np.isfinite(losses))


# ---------------------------------------------------------------------------
# sparse_replicated local mode (submodel replicas in the trainer)
# ---------------------------------------------------------------------------


def test_trainer_sparse_local_auto_resolves_to_submodel(small_ds):
    """With axis-0 feature tables spanning the dataset id space, "auto" picks
    gathered submodel replicas; forcing dense replicas still works."""
    tr = _make_trainer(small_ds, sparse=True)
    assert tr._sparse_local == "sparse_replicated"
    assert tr._sparse_paths == [("w",)]
    tr_dense = _make_trainer(small_ds, sparse=True, sparse_local="replicated")
    assert tr_dense._sparse_local == "replicated"
    with pytest.raises(ValueError, match="sparse_local"):
        _make_trainer(small_ds, sparse=True, sparse_local="bogus")


@pytest.mark.parametrize("alg", ["fedsubavg", "fedavg", "fedprox", "fedadam"])
def test_trainer_submodel_replicas_match_dense_replicas(small_ds, alg):
    """The gathered-submodel local trainer reproduces dense-replica local
    training to 1e-5 over a multi-round run (same RNG stream) for the sparse
    apply path AND the densify-at-boundary server optimizers."""
    tr_sub = _make_trainer(small_ds, sparse=True, alg=alg)
    tr_rep = _make_trainer(small_ds, sparse=True, alg=alg,
                           sparse_local="replicated")
    losses_sub = [tr_sub.run_round() for _ in range(5)]
    losses_rep = [tr_rep.run_round() for _ in range(5)]
    np.testing.assert_allclose(losses_sub, losses_rep, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(unbox(tr_sub.state.params)),
                    jax.tree.leaves(unbox(tr_rep.state.params))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_trainer_submodel_engine_matches_loop(small_ds):
    """run_rounds (one lax.scan) on the submodel path == per-round loop."""
    tr_loop = _make_trainer(small_ds, sparse=True)
    tr_scan = _make_trainer(small_ds, sparse=True)
    losses_loop = [tr_loop.run_round() for _ in range(5)]
    losses_scan = tr_scan.run_rounds(5)
    np.testing.assert_allclose(losses_scan, losses_loop, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(unbox(tr_loop.state.params)),
                    jax.tree.leaves(unbox(tr_scan.state.params))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_submodel_local_trainer_emits_rowsparse_at_capacity():
    """Deltas come out of local training already RowSparse on the client's
    sub_ids — (K, capacity) ids, (K, capacity, D) rows; no dense (K, V, D)."""
    from repro.federated import (cohort_submodel_deltas, derive_sub_ids,
                                 make_submodel_local_trainer, pow2_capacity)
    from repro.models.recsys import lstm_loss, make_lstm_params
    v, e, k, i, b, s = 64, 4, 3, 2, 2, 5
    params = make_lstm_params(v, emb_dim=e, hidden=6, layers=1,
                              rng=jax.random.PRNGKey(0))
    cfg = FedConfig(num_clients=8, clients_per_round=k, local_iters=i, lr=0.2)
    rng = np.random.default_rng(3)
    tokens = rng.integers(-1, v, (k, i, b, s)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens),
             "label": jnp.asarray(rng.integers(0, 2, (k, i, b)), jnp.int32)}
    counts = np.asarray(count_sub_ids(jnp.asarray(tokens.reshape(k, -1)), v))
    capacity = pow2_capacity(int(counts.max()))
    sub_ids = derive_sub_ids(jnp.asarray(tokens.reshape(k, -1)), v, capacity)
    local = make_submodel_local_trainer(lstm_loss, cfg, [("embedding",)],
                                        ("tokens",))
    deltas = jax.jit(cohort_submodel_deltas, static_argnums=0)(
        local, params, batch, sub_ids)
    rs = deltas["embedding"]
    assert rs.ids.shape == (k, capacity)
    assert rs.rows.shape == (k, capacity, e)
    assert rs.num_rows == v
    # padding rows are exactly zero; support matches the client's sub_ids
    ids_np, rows_np = np.asarray(rs.ids), np.asarray(rs.rows)
    np.testing.assert_array_equal(ids_np, np.asarray(sub_ids))
    assert np.all(rows_np[ids_np < 0] == 0)
    assert np.any(rows_np[ids_np >= 0] != 0)


# ---------------------------------------------------------------------------
# satellite regressions: int8 keys, comm pricing, run() bookkeeping
# ---------------------------------------------------------------------------


def test_quantize_tree_int8_independent_per_leaf(rng):
    """Regression: two feature tables in one round must draw INDEPENDENT
    stochastic-rounding noise — the old server path reused one key for every
    tree leaf, correlating the quantization errors across tables."""
    from repro.sparse import quantize_tree_int8
    v, r, d = 30, 6, 4
    ids = jnp.asarray([0, 4, 8, 12, 16, -1], jnp.int32)
    rows = jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
    rows = rows * (np.asarray(ids) >= 0)[:, None]
    rs = RowSparse(ids, rows, v)
    tree = {"a": rs, "b": RowSparse(ids, rows, v), "dense": jnp.ones((3,))}
    out = quantize_tree_int8(tree, jax.random.PRNGKey(0))
    # identical inputs, different leaves -> different rounding noise
    assert not np.array_equal(np.asarray(out["a"].q), np.asarray(out["b"].q))
    # dense leaves pass through untouched; same tree+key is deterministic
    np.testing.assert_array_equal(np.asarray(out["dense"]), np.ones(3))
    out2 = quantize_tree_int8(tree, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out["a"].q), np.asarray(out2["a"].q))
    # both leaves still dequantize to within one quantization step
    from repro.sparse import dequantize_rows
    for k in ("a", "b"):
        dq = np.asarray(dequantize_rows(out[k]).rows)
        scales = np.abs(np.asarray(rows)).max(-1, keepdims=True) / 127.0
        assert np.all(np.abs(dq - np.asarray(rows))
                      <= np.maximum(scales, 1e-6) + 1e-6)


def test_trainer_int8_two_tables_draw_independent_noise():
    """End-to-end regression (fails pre-fix): a model with two identical
    feature tables receiving identical deltas must end the round with
    DIFFERENT tables under sparse_int8 — correlated rounding noise would
    keep them bit-identical forever."""
    from repro.sharding.logical import Param
    ds = make_movielens_like(num_clients=30, num_items=32, mean_samples=12)

    def mk(rng):
        w = 0.01 * jax.random.normal(rng, (ds.num_features, 2), jnp.float32)
        # equal values, distinct buffers (donation rejects aliased leaves)
        return {"wa": Param(w, ("vocab", "embed")),
                "wb": Param(w.copy(), ("vocab", "embed")),
                "b": Param(jnp.zeros((1,), jnp.float32), (None,))}

    def loss(params, batch):
        p = unbox(params)
        feats = batch["features"]
        valid = (feats >= 0).astype(jnp.float32)[..., None]
        va = p["wa"][jnp.maximum(feats, 0)] * valid
        vb = p["wb"][jnp.maximum(feats, 0)] * valid
        # asymmetric column weights keep the per-row delta elements at
        # DISTINCT magnitudes: only the row max quantizes exactly (+-127),
        # the rest genuinely draw stochastic-rounding noise
        cw = jnp.asarray([1.0, 0.61], jnp.float32)
        logit = ((va * cw).sum(axis=(-2, -1))
                 + (vb * cw).sum(axis=(-2, -1))) + p["b"][0]
        lab = batch["label"].astype(jnp.float32)
        per = jnp.maximum(logit, 0) - logit * lab + jnp.log1p(
            jnp.exp(-jnp.abs(logit)))
        m = batch.get("sample_mask", jnp.ones_like(per))
        return (per * m).sum() / jnp.maximum(m.sum(), 1.0)

    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=5,
                    local_iters=2, local_batch=4, lr=0.5,
                    algorithm="fedsubavg", sparse=True, sparse_int8=True)
    tr = FederatedTrainer(ds, mk, loss, cfg)
    tr.run_round()
    wa = np.asarray(unbox(tr.state.params)["wa"])
    wb = np.asarray(unbox(tr.state.params)["wb"])
    assert not np.array_equal(wa, wb), \
        "identical tables stayed identical: int8 noise is correlated"


def test_leaf_wire_bytes_containers(rng):
    """Regression: leaf_wire_bytes must price empty containers (0 bytes, not
    IndexError) and multi-leaf subtrees (sum, not first-leaf-only)."""
    from repro.sparse import leaf_wire_bytes
    from repro.sparse.compress import quantize_rows_int8 as q8
    v, r, d = 50, 5, 3
    ids = jnp.asarray([1, 7, 9, -1, -1], jnp.int32)
    rows = jnp.asarray(rng.normal(size=(r, d)), jnp.float32)
    rows = rows * (np.asarray(ids) >= 0)[:, None]
    rs = RowSparse(ids, rows, v)
    assert leaf_wire_bytes(rs) == 3 * (4 + d * 4)
    qr = q8(rs, jax.random.PRNGKey(0))
    assert leaf_wire_bytes(qr) == 3 * (4 + d + 4)
    arr = jnp.zeros((4, 6), jnp.float32)
    assert leaf_wire_bytes(arr) == 4 * 6 * 4
    # empty containers: 0 bytes (the old code crashed on leaves[0])
    assert leaf_wire_bytes([]) == 0.0
    assert leaf_wire_bytes({}) == 0.0
    assert leaf_wire_bytes(()) == 0.0
    # nested dict: the SUM of its leaves (old code priced only the first)
    nested = {"x": arr, "y": {"z": jnp.zeros((2, 2), jnp.float32), "rs": rs}}
    want = 4 * 6 * 4 + 2 * 2 * 4 + 3 * (4 + d * 4)
    assert leaf_wire_bytes(nested) == want
    assert tree_wire_bytes(nested) == want
    # scalar leaf
    assert leaf_wire_bytes(np.float32(1.0)) == 4.0


def test_trainer_downlink_priced_at_gathered_submodel(small_ds):
    """Honest downlink: submodel mode ships the gathered capacity-row buffer;
    dense-replica mode ships the full table. The dense baseline carries the
    local_iters factor (I model round-trips at I=1 to match one I-step round)."""
    tr = _make_trainer(small_ds, sparse=True)          # local_iters=3
    tr.run_round()
    c = tr.comm_log[-1]
    dense_bytes, static, row_payload, _ = tr._comm_meta
    k = tr.cfg.clients_per_round
    # dense baseline: K * model * I, both directions
    assert c.bytes_up_dense == pytest.approx(k * dense_bytes * 3)
    assert c.bytes_down_dense == pytest.approx(k * dense_bytes * 3)
    # downlink rows = the shared capacity bucket (clamped to the table size:
    # the pow2 padding past V is never materialised on the wire), same for
    # every client
    rows_down = (c.bytes_down_sparse - k * static) / (4 + row_payload)
    assert rows_down % k == 0
    per_client = int(rows_down / k)
    assert 8 <= per_client <= small_ds.num_features
    assert (per_client == small_ds.num_features
            or (per_client & (per_client - 1)) == 0)
    # density still reports the true submodel size, not the padded bucket
    assert 0 < c.density < 1
    # dense-replica local mode prices the full-table broadcast it performs:
    # the whole payload, but NO per-row id bytes (a contiguous table ships
    # no row indices) — so at local_iters=1 it would equal the dense model
    tr_rep = _make_trainer(small_ds, sparse=True, sparse_local="replicated")
    tr_rep.run_round()
    c_rep = tr_rep.comm_log[-1]
    want = k * static + k * small_ds.num_features * row_payload
    assert c_rep.bytes_down_sparse == pytest.approx(want)
    assert c_rep.bytes_down_sparse == pytest.approx(c_rep.bytes_down_dense / 3)
    assert c_rep.bytes_down_sparse > c.bytes_down_sparse
    # regression: when the pow2 bucket overshoots the table (clients touching
    # nearly all of V), the priced download clamps to the table size — the
    # submodel can never cost more wire than shipping the whole table
    over_cap = pow2_capacity(small_ds.num_features)       # > V by construction
    assert over_cap > small_ds.num_features
    tr._log_sparse_comm(np.full(k, small_ds.num_features - 1), over_cap)
    c_over = tr.comm_log[-1]
    assert c_over.bytes_down_sparse == pytest.approx(want)
    assert c_over.bytes_down_sparse <= c_rep.bytes_down_sparse


def test_run_round_numbers_continue_across_calls(small_ds):
    """Regression: a second run() (or mixing run_round with run) must append
    RoundRecords whose round numbers continue from the global counter instead
    of restarting at 0 and colliding with existing history."""
    tr = _make_trainer(small_ds, sparse=True)
    tr.run(4, eval_every=2)
    tr.run(4, eval_every=2)
    rounds = [r.round for r in tr.history]
    assert rounds == [2, 4, 6, 8]
    tr.run_round()
    tr.run(2, eval_every=2)
    rounds = [r.round for r in tr.history]
    assert rounds == [2, 4, 6, 8, 11]
    assert rounds == sorted(rounds) and len(set(rounds)) == len(rounds)
    assert tr._rounds_run == 11


# ---------------------------------------------------------------------------
# end-to-end: simulation.make_round_step sparse mode == fedsgd
# ---------------------------------------------------------------------------


def test_simulation_sparse_mode_matches_fedsgd():
    cfg = get_smoke_config("qwen2_5_14b").replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    fed = FedConfig(num_clients=64, clients_per_round=4, lr=0.1,
                    algorithm="fedsubavg")
    heat = jnp.maximum(
        jax.random.randint(jax.random.PRNGKey(1), (cfg.vocab_size,), 0, 30)
        .astype(jnp.float32), 0)
    b, s = 4, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                          cfg.vocab_size),
             "labels": jnp.ones((b, s), jnp.int32),
             "mask": jnp.ones((b, s), jnp.float32),
             "heat_vocab": heat}
    dense_step = jax.jit(make_round_step(api.loss, params, fed, mode="fedsgd"))
    sparse_step = jax.jit(make_round_step(api.loss, params, fed, mode="sparse"))
    pd_, md = dense_step(params, batch)
    ps_, ms = sparse_step(params, batch)
    np.testing.assert_allclose(float(ms["loss"]), float(md["loss"]), rtol=1e-6)
    assert 0 < float(ms["density"]) <= 1
    for a, b_ in zip(jax.tree.leaves(unbox(pd_)), jax.tree.leaves(unbox(ps_))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)


def test_simulation_sparse_mode_without_explicit_labels():
    """Regression: the LM losses derive next-token targets from
    batch["tokens"] when "labels" is absent; sparse mode must pin targets to
    the ORIGINAL ids before the submodel swap remaps tokens to row slots."""
    cfg = get_smoke_config("qwen2_5_14b").replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    fed = FedConfig(num_clients=64, clients_per_round=4, lr=0.1,
                    algorithm="fedsubavg")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 16), 0,
                                          cfg.vocab_size),
             "heat_vocab": jnp.full((cfg.vocab_size,), 5.0)}
    dense_step = jax.jit(make_round_step(api.loss, params, fed, mode="fedsgd"))
    sparse_step = jax.jit(make_round_step(api.loss, params, fed, mode="sparse"))
    pd_, md = dense_step(params, batch)
    ps_, ms = sparse_step(params, batch)
    np.testing.assert_allclose(float(ms["loss"]), float(md["loss"]), rtol=1e-6)
    for a, b_ in zip(jax.tree.leaves(unbox(pd_)), jax.tree.leaves(unbox(ps_))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)


def test_simulation_sparse_short_training_run_matches():
    """Losses over a short multi-round run agree to >= 1e-5 (ISSUE criterion)."""
    cfg = get_smoke_config("qwen2_5_14b").replace(dtype="float32")
    api = build_model(cfg)
    fed = FedConfig(num_clients=64, clients_per_round=4, lr=0.1,
                    algorithm="fedsubavg")
    heat = jnp.maximum(
        jax.random.randint(jax.random.PRNGKey(1), (cfg.vocab_size,), 0, 30)
        .astype(jnp.float32), 1)

    def run(mode):
        params = api.init(jax.random.PRNGKey(0))
        step = jax.jit(make_round_step(api.loss, params, fed, mode=mode))
        losses = []
        for r in range(4):
            key = jax.random.PRNGKey(100 + r)
            batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
                     "labels": jnp.ones((4, 16), jnp.int32),
                     "mask": jnp.ones((4, 16), jnp.float32),
                     "heat_vocab": heat}
            params, m = step(params, batch)
            losses.append(float(m["loss"]))
        return losses

    np.testing.assert_allclose(run("sparse"), run("fedsgd"), rtol=1e-5)


def test_wire_bytes_accounting(rng):
    v, d, r = 100, 8, 10
    ids = jnp.asarray(list(range(r)), jnp.int32)
    rs = RowSparse(ids, jnp.asarray(rng.normal(size=(r, d)), jnp.float32), v)
    assert tree_wire_bytes({"e": rs}) == r * (4 + d * 4)
    dense = jnp.zeros((v, d), jnp.float32)
    assert tree_wire_bytes({"e": dense}) == v * d * 4
    qr = quantize_rows_int8(rs, jax.random.PRNGKey(0))
    assert tree_wire_bytes({"e": qr}) == r * (4 + d + 4)
