"""Recurrent-block equivalences: chunked (train) vs single-step (decode)
forms must implement the same recurrence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import ssm as S
from repro.models import xlstm as X


def test_ssd_chunked_matches_stepwise(rng):
    b, s, h, p, n = 2, 32, 3, 8, 4
    x = jnp.asarray(rng.normal(0, 1, (b, s, h, p)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.01, 1.0, (b, s, h)), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 1, (b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (b, s, n)), jnp.float32)

    y_chunk, st_chunk = S.ssd_chunked(x, a, bm, cm, chunk=8)

    # stepwise reference
    state = np.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        decay = np.exp(np.asarray(a[:, t]))                     # (b,h)
        state = state * decay[..., None, None] + np.einsum(
            "bn,bhp->bhpn", np.asarray(bm[:, t]), np.asarray(x[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", state, np.asarray(cm[:, t])))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), state, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_size_invariance(rng):
    b, s, h, p, n = 1, 24, 2, 4, 4
    x = jnp.asarray(rng.normal(0, 1, (b, s, h, p)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 1, (b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (b, s, n)), jnp.float32)
    y1, s1 = S.ssd_chunked(x, a, bm, cm, chunk=4)
    y2, s2 = S.ssd_chunked(x, a, bm, cm, chunk=24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-5)


def test_mamba2_block_decode_continuity(rng):
    """Prefill on s tokens then 1 decode step == prefill on s+1 tokens."""
    cfg = get_smoke_config("zamba2_1_2b").replace(dtype="float32")
    from repro.sharding.logical import ParamFactory, unbox
    pf = ParamFactory(rng=jax.random.PRNGKey(0), abstract=False, dtype=jnp.float32)
    mp = unbox(S.make_mamba2_params(pf, cfg))
    b, s = 1, 12
    x = jnp.asarray(rng.normal(0, 0.1, (b, s + 1, cfg.d_model)), jnp.float32)
    y_full, _ = S.mamba2_block(cfg, mp, x, chunk=4)
    y_pre, st = S.mamba2_block(cfg, mp, x[:, :s], chunk=4)
    y_step, _ = S.mamba2_block(cfg, mp, x[:, s:s + 1], state=st, single_step=True)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]), np.asarray(y_full[:, s]),
                               rtol=1e-3, atol=1e-3)


def test_mlstm_chunked_matches_step(rng):
    b, s, h, hd = 1, 16, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    li = jnp.asarray(rng.normal(0, 1, (b, s, h)), jnp.float32)
    lf = jnp.asarray(rng.normal(-0.5, 0.5, (b, s, h)), jnp.float32)

    y_chunk, st_chunk = X.mlstm_cell_chunked(q, k, v, li, lf, chunk=4)

    st = X.MLSTMState(jnp.zeros((b, h, hd, hd)), jnp.zeros((b, h, hd)),
                      jnp.full((b, h), -1e30))
    ys = []
    for t in range(s):
        y, st = X.mlstm_cell_step(q[:, t], k[:, t], v[:, t], li[:, t], lf[:, t], st)
        ys.append(np.asarray(y))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_chunk.c), np.asarray(st.c), rtol=1e-3,
                               atol=1e-3)


def test_slstm_state_continuity(rng):
    cfg = get_smoke_config("xlstm_350m").replace(dtype="float32")
    from repro.sharding.logical import ParamFactory, unbox
    pf = ParamFactory(rng=jax.random.PRNGKey(0), abstract=False, dtype=jnp.float32)
    sp = unbox(X.make_slstm_params(pf, cfg))
    b, s = 2, 10
    x = jnp.asarray(rng.normal(0, 0.5, (b, s + 4, cfg.d_model)), jnp.float32)
    y_full, _ = X.slstm_scan(cfg, sp, x)
    y_a, st = X.slstm_scan(cfg, sp, x[:, :s])
    y_b, _ = X.slstm_scan(cfg, sp, x[:, s:], state=st)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_full[:, s:]),
                               rtol=1e-4, atol=1e-4)
