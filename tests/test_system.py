"""End-to-end system behaviour: federated LM training on the host device and
the serving path, exercising the same code the pod dry-run lowers."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FedConfig, get_smoke_config
from repro.data import make_lm_federated
from repro.federated import make_round_step
from repro.models import build_model
from repro.sharding.logical import unbox


def test_federated_lm_training_reduces_loss():
    """A tiny decoder LM trained with FedSubAvg rounds (fedsgd mode) on a
    Zipf-heat federated corpus: loss must drop substantially."""
    cfg = get_smoke_config("qwen2_5_14b").replace(dtype="float32", vocab_size=512)
    api = build_model(cfg)
    ds = make_lm_federated(num_clients=64, vocab=cfg.vocab_size, seq_len=32,
                           samples_per_client=2)
    fed = FedConfig(num_clients=ds.num_clients, clients_per_round=8,
                    lr=0.05, algorithm="fedsubavg")
    params = api.init(jax.random.PRNGKey(0))
    step = jax.jit(make_round_step(api.loss, params, fed, mode="fedsgd"))
    heat = jnp.asarray(ds.heat.counts, jnp.float32)
    rng = np.random.default_rng(0)

    losses = []
    for r in range(40):
        ids = rng.choice(ds.num_clients, size=8, replace=False)
        toks = ds.client_data["tokens"][ids, rng.integers(0, 2, size=8)]
        batch = {"tokens": jnp.asarray(toks), "heat_vocab": heat}
        params, metrics = step(params, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 2.0, losses[::6]
    assert np.isfinite(losses).all()


def test_fedsubavg_vs_fedavg_on_lm():
    """Heat correction accelerates the embedding-heavy LM too."""
    cfg = get_smoke_config("qwen2_5_14b").replace(dtype="float32", vocab_size=512,
                                                  num_layers=2)
    api = build_model(cfg)
    ds = make_lm_federated(num_clients=64, vocab=cfg.vocab_size, seq_len=32,
                           samples_per_client=2, zipf_a=1.5)
    heat = jnp.asarray(ds.heat.counts, jnp.float32)
    rng_master = np.random.default_rng(1)
    order = [rng_master.choice(ds.num_clients, size=8, replace=False) for _ in range(25)]

    def run(correct):
        fed = FedConfig(num_clients=ds.num_clients, clients_per_round=8, lr=0.05,
                        algorithm="fedsubavg" if correct else "fedavg")
        params = api.init(jax.random.PRNGKey(0))
        step = jax.jit(make_round_step(api.loss, params, fed, mode="fedsgd",
                                       correct=correct))
        rng = np.random.default_rng(2)
        losses = []
        for ids in order:
            toks = ds.client_data["tokens"][ids, rng.integers(0, 2, size=8)]
            batch = {"tokens": jnp.asarray(toks), "heat_vocab": heat}
            params, metrics = step(params, batch)
            losses.append(float(metrics["loss"]))
        # single-round losses are cohort-sampled and noisy; average the tail
        return float(np.mean(losses[-5:]))

    l_sub = run(True)
    l_avg = run(False)
    assert l_sub < l_avg, (l_sub, l_avg)


def test_serve_path_greedy_decode():
    """Prefill + N greedy decode steps produce a stable token stream."""
    cfg = get_smoke_config("mixtral_8x22b").replace(dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    cache = api.init_cache(b, s + 8)
    logits, cache = jax.jit(api.prefill)(params, {"tokens": toks}, cache)
    decode = jax.jit(api.decode_step)
    outs = []
    for _ in range(8):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits, cache = decode(params, cache, {"tokens": nxt})
        outs.append(nxt)
        assert not bool(jnp.isnan(logits).any())
    assert int(cache.pos) == s + 8
    assert jnp.stack(outs).shape == (8, b)


def test_heat_scatter_in_training_path(rng):
    """The Pallas kernel reproduces the autodiff embedding update: sparse
    token-grad scatter + heat scale == dense grad row scaling."""
    from repro.kernels import ops
    cfg = get_smoke_config("qwen3_32b").replace(dtype="float32", num_layers=2)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    heat = jnp.asarray(rng.integers(1, 20, cfg.vocab_size), jnp.float32)
    n = 100.0
    factor = jnp.where(heat > 0, n / jnp.maximum(heat, 1.0), 0.0)

    # the kernel consumes token-level grads (the VJP of the embedding gather);
    # scatter(token_grads) * factor must equal the dense autodiff row update
    tok_grads = jnp.asarray(rng.normal(0, 1, (b * s, cfg.d_model)), jnp.float32)
    out = ops.heat_scatter(toks.reshape(-1), tok_grads, heat, n, cfg.vocab_size,
                           v_blk=128, t_blk=64)
    want = jnp.zeros((cfg.vocab_size, cfg.d_model)).at[toks.reshape(-1)].add(tok_grads)
    want = want * factor[:, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)
