"""Telemetry plane (ISSUE 6): in-jit RoundTelemetry + host-side sinks.

Three layers under test:

1. the pure counter helpers (``repro.telemetry.round``, plus the
   ``membership`` primitive they lean on);
2. the in-jit ``RoundTelemetry`` threaded through the three execution
   paths — plain round step, the ``lax.scan`` engine, and
   ``CohortSharding`` shard_map rounds — with the acceptance parity pin:
   enabling telemetry changes NO losses, parameters, or RNG draws;
3. the host side: ``TraceSink`` JSONL events, the compile/steady
   ``PhaseTimer`` split surfaced as ``RoundRecord.compile_time``, the
   logging-based verbose reporter, and ``run(profile_dir=...)``.

CI's forced-8-device step re-runs this file so the sharded cases see a
real multi-shard mesh; on one device they still exercise one shard.
"""
import dataclasses
import functools
import glob
import json
import logging
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FedConfig
from repro.core.algorithms import ServerState
from repro.data import make_movielens_like
from repro.federated import (CohortSharding, FederatedTrainer, FedSgdLocal,
                             RoundPlan, RowSparseTransport, ServerUpdate,
                             SubmodelReplicatedLocal, make_round_step)
from repro.federated.plan import build_round_step
from repro.launch.mesh import make_cohort_mesh
from repro.models.recsys import lr_loss, make_lr_params
from repro.sharding.logical import Param, unbox
from repro.sparse.rowsparse import membership, unique_ids_padded
from repro.telemetry import (HEAT_BUCKETS, PhaseTimer, RoundTelemetry,
                             TraceSink, drop_stats, heat_histogram,
                             read_events, split_rounds, valid_feature_ids)

NDEV = len(jax.devices())
V, D, K, I, B, S = 32, 4, 4, 2, 2, 6


# ---------------------------------------------------------------------------
# tiny model shared by the plan-level tests
# ---------------------------------------------------------------------------


def _params():
    rng = jax.random.PRNGKey(0)
    emb = jax.random.normal(rng, (V, D)) * 0.1
    w = jax.random.normal(jax.random.fold_in(rng, 1), (D,)) * 0.1
    return {"emb": Param(emb, ("vocab", "d")), "w": Param(w, (None,))}


def _loss(params, batch):
    emb, w = params["emb"].value, params["w"].value
    x = jnp.take(emb, jnp.maximum(batch["tokens"], 0), axis=0).mean(axis=-2)
    return jnp.mean(((x @ w) - batch["label"]) ** 2)


def _cfg(k=K):
    return FedConfig(num_clients=16, clients_per_round=k, local_iters=I,
                     local_batch=B, lr=0.1, sparse=True)


def _batch(seed, shape):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, V, shape), jnp.int32),
            "label": jnp.asarray(rng.normal(size=shape[:-1]).astype(np.float32)),
            "heat_vocab": jnp.asarray(
                np.maximum(rng.integers(0, 10, V), 1).astype(np.float32))}


_MODE_SHAPES = {"fedsgd": (B * K, S), "sparse": (B * K, S),
                "replicated": (K, I, B, S), "sparse_replicated": (K, I, B, S)}


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(unbox(a)), jax.tree.leaves(unbox(b))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# counter helpers
# ---------------------------------------------------------------------------


def test_membership_matches_isin():
    rng = np.random.default_rng(3)
    ids = unique_ids_padded(jnp.asarray(rng.integers(0, V, 20), jnp.int32), 16)
    tokens = jnp.asarray(rng.integers(-1, V, 40), jnp.int32)
    valid = np.asarray(ids)[np.asarray(ids) >= 0]
    expect = np.isin(np.asarray(tokens), valid) & (np.asarray(tokens) >= 0)
    np.testing.assert_array_equal(np.asarray(membership(tokens, ids)), expect)


def test_membership_all_padding_ids():
    ids = jnp.full((8,), -1, jnp.int32)
    tokens = jnp.asarray([0, 3, -1, 7], jnp.int32)
    assert not np.asarray(membership(tokens, ids)).any()


def test_valid_feature_ids_clamps_out_of_range():
    ids = jnp.asarray([-5, -1, 0, V - 1, V, V + 7], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(valid_feature_ids(ids, V)), [-1, -1, 0, V - 1, -1, -1])


def test_drop_stats_exact_vs_host():
    rng = np.random.default_rng(7)
    feats = rng.integers(-1, V, (K, 24)).astype(np.int32)
    cap = 4
    sub = jax.vmap(lambda f: unique_ids_padded(f, cap))(jnp.asarray(feats))
    dropped, mass = drop_stats(jnp.asarray(feats), sub, V)
    for k in range(K):
        row = feats[k][feats[k] >= 0]
        kept = np.asarray(sub[k])[np.asarray(sub[k]) >= 0]
        assert int(dropped[k]) == max(len(np.unique(row)) - len(kept), 0)
        assert float(mass[k]) == float((~np.isin(row, kept)).sum())


def test_drop_stats_zero_when_fitting():
    rng = np.random.default_rng(8)
    feats = rng.integers(-1, V, (K, 24)).astype(np.int32)
    sub = jax.vmap(lambda f: unique_ids_padded(f, V))(jnp.asarray(feats))
    dropped, mass = drop_stats(jnp.asarray(feats), sub, V)
    assert int(np.asarray(dropped).sum()) == 0
    assert float(np.asarray(mass).sum()) == 0.0


def test_heat_histogram_log2_buckets():
    heat = jnp.asarray([1.0, 2.0, 3.0, 4.0, 100.0], jnp.float32)
    ids = jnp.asarray([0, 1, 2, 3, 4, -1, -1], jnp.int32)
    hist = np.asarray(heat_histogram(heat, ids, HEAT_BUCKETS))
    assert hist.shape == (HEAT_BUCKETS,)
    # h=1 -> bucket 0; h in {2,3} -> 1; h=4 -> 2; h=100 -> 6; pads dropped
    assert hist[0] == 1 and hist[1] == 2 and hist[2] == 1 and hist[6] == 1
    assert hist.sum() == 5


# ---------------------------------------------------------------------------
# host-side primitives: PhaseTimer, TraceSink
# ---------------------------------------------------------------------------


def test_phase_timer_splits_compile_from_steady():
    t = PhaseTimer()
    t.add("round", 5.0, compile=True)
    t.add("round", 1.0)
    t.add("round", 3.0)
    assert t.mean("round") == pytest.approx(2.0)      # steady-state only
    s = t.summary()["round"]
    assert s["compile_s"] == pytest.approx(5.0) and s["compile_count"] == 1
    assert s["count"] == 2 and s["total_s"] == pytest.approx(4.0)


def test_trace_sink_jsonl_roundtrip(tmp_path):
    path = tmp_path / "trace.jsonl"
    with TraceSink(str(path)) as sink:
        sink.emit({"event": "round", "round": 1, "union_size": 7})
        sink.emit({"event": "record", "round": 1, "train_loss": 0.5})
        assert len(sink.events) == 2
    events = read_events(str(path))
    assert [e["event"] for e in events] == ["round", "record"]
    assert events[0]["union_size"] == 7


def test_trace_sink_json_safe_for_device_scalars(tmp_path):
    """Satellite pin: emitting a telemetry dict whose leaves are jnp / numpy
    scalars and 0-d arrays must write valid JSON (coerced via the default=
    serializer) and round-trip through read_events as plain Python."""
    path = tmp_path / "trace.jsonl"
    with TraceSink(str(path)) as sink:
        sink.emit({"event": "round", "round": jnp.asarray(3, jnp.int32),
                   "loss": jnp.float32(0.25),
                   "density": np.float64(0.5),
                   "union": np.asarray(7),                 # 0-d ndarray
                   "hist": jnp.arange(3, dtype=jnp.float32),
                   "nested": {"occupancy": jnp.asarray(2)}})
    (event,) = read_events(str(path))
    assert event["round"] == 3 and isinstance(event["round"], int)
    assert event["loss"] == pytest.approx(0.25)
    assert event["density"] == pytest.approx(0.5)
    assert event["union"] == 7
    assert event["hist"] == [0.0, 1.0, 2.0]
    assert event["nested"]["occupancy"] == 2
    # genuinely unserialisable junk still fails loudly
    with pytest.raises(TypeError):
        with TraceSink(str(tmp_path / "bad.jsonl")) as sink:
            sink.emit({"event": "round", "obj": object()})


def test_trace_sink_report_goes_through_logging(caplog):
    sink = TraceSink()
    with caplog.at_level(logging.INFO, logger="repro.telemetry"):
        sink.report("hello round")
    assert any("hello round" in r.message for r in caplog.records)
    assert all(r.name == "repro.telemetry" for r in caplog.records)


# ---------------------------------------------------------------------------
# parity pin: telemetry on/off is bit-identical (plain + scan + sharded)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", sorted(_MODE_SHAPES))
def test_round_step_parity_all_modes(mode):
    params = _params()
    batch = _batch(11, _MODE_SHAPES[mode])
    s0 = jax.jit(make_round_step(_loss, params, _cfg(), mode=mode))
    s1 = jax.jit(make_round_step(_loss, params, _cfg(), mode=mode,
                                 telemetry=True))
    p0, m0 = s0(params, batch)
    p1, m1 = s1(params, batch)
    assert "telemetry" not in m0
    _assert_trees_equal(p0, p1)
    np.testing.assert_array_equal(np.asarray(m0["loss"]),
                                  np.asarray(m1["loss"]))
    tel = m1["telemetry"]
    assert isinstance(tel, RoundTelemetry)
    assert int(tel.dropped_ids) == 0 and float(tel.dropped_mass) == 0.0
    assert 0.0 <= float(tel.density) <= 1.0
    if mode.startswith("sparse"):
        assert int(tel.union_size) > 0
        assert float(tel.heat_hist.sum()) == float(tel.union_size)
    assert float(tel.delta_norm_pre) > 0.0


def test_scan_engine_parity_and_stacking():
    """Telemetry rides the lax.scan: fields gain a leading round axis,
    split_rounds recovers per-round host events, losses stay identical."""
    n = 3
    params = _params()
    cfg = _cfg()
    plan = RoundPlan(SubmodelReplicatedLocal(), RowSparseTransport(),
                     ServerUpdate("fedsubavg"))
    batches = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[_batch(50 + r, (K, I, B, S)) for r in range(n)])
    feats = batches["tokens"].reshape(n * K, -1)
    sub = jax.vmap(lambda f: unique_ids_padded(f, V))(feats)
    sub = sub.reshape(n, K, V)

    def engine(telemetry):
        step = build_round_step(plan, _loss, params, cfg, telemetry=telemetry)
        return jax.jit(lambda s, bs, ids: jax.lax.scan(
            lambda c, xs: step(c, *xs), s, (bs, ids)))

    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    s0, m0 = engine(False)(state, batches, sub)
    s1, m1 = engine(True)(state, batches, sub)
    _assert_trees_equal(s0.params, s1.params)
    np.testing.assert_array_equal(np.asarray(m0["loss"]),
                                  np.asarray(m1["loss"]))
    tel = m1["telemetry"]
    assert tel.union_size.shape == (n,)
    events = split_rounds(tel, n)
    assert len(events) == n
    assert all(e["dropped_ids"] == 0 for e in events)
    assert all(len(e["heat_hist"]) == HEAT_BUCKETS for e in events)


@pytest.mark.skipif(NDEV < 2, reason="needs a multi-device mesh")
def test_sharded_parity_on_off():
    params = _params()
    cfg = _cfg(k=NDEV)
    plan = RoundPlan(SubmodelReplicatedLocal(), RowSparseTransport(),
                     ServerUpdate("fedsubavg"),
                     sharding=CohortSharding(make_cohort_mesh()))
    batch = _batch(21, (NDEV, I, B, S))
    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    s0, m0 = jax.jit(build_round_step(plan, _loss, params, cfg))(state, batch)
    s1, m1 = jax.jit(build_round_step(plan, _loss, params, cfg,
                                      telemetry=True))(state, batch)
    _assert_trees_equal(s0.params, s1.params)
    np.testing.assert_array_equal(np.asarray(m0["loss"]),
                                  np.asarray(m1["loss"]))
    tel = m1["telemetry"]
    assert tel.shard_union_sizes is not None
    assert tel.shard_union_sizes.shape == (NDEV,)
    assert int(tel.dropped_ids) == 0


# ---------------------------------------------------------------------------
# capacity-overflow accounting: exact counts on all three paths
# ---------------------------------------------------------------------------


def _expected_drops(feats, cap):
    """Host-side truth: per-client (distinct - kept, occurrence mass)."""
    dropped = mass = 0
    for row in np.asarray(feats):
        row = row[row >= 0]
        kept = np.asarray(unique_ids_padded(jnp.asarray(row), cap))
        kept = kept[kept >= 0]
        dropped += max(len(np.unique(row)) - len(kept), 0)
        mass += int((~np.isin(row, kept)).sum())
    return dropped, mass


def _overflow_case(k=K, seed=31, cap=4):
    batch = _batch(seed, (k, I, B, S))
    feats = batch["tokens"].reshape(k, -1)
    sub_small = jax.vmap(lambda f: unique_ids_padded(f, cap))(feats)
    sub_fit = jax.vmap(lambda f: unique_ids_padded(f, V))(feats)
    return batch, feats, sub_small, sub_fit


def test_overflow_exact_count_plain():
    params = _params()
    plan = RoundPlan(SubmodelReplicatedLocal(), RowSparseTransport(),
                     ServerUpdate("fedsubavg"))
    step = jax.jit(build_round_step(plan, _loss, params, _cfg(),
                                    telemetry=True))
    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    batch, feats, sub_small, sub_fit = _overflow_case()
    exp_dropped, exp_mass = _expected_drops(feats, 4)
    assert exp_dropped > 0

    _, m = step(state, batch, sub_small)
    tel = m["telemetry"]
    assert int(tel.dropped_ids) == exp_dropped
    assert float(tel.dropped_mass) == float(exp_mass)
    assert int(np.asarray(tel.dropped_per_client).sum()) == exp_dropped

    _, m2 = step(state, batch, sub_fit)
    assert int(m2["telemetry"].dropped_ids) == 0
    assert float(m2["telemetry"].dropped_mass) == 0.0


def test_overflow_exact_count_scan_engine():
    n = 2
    params = _params()
    plan = RoundPlan(SubmodelReplicatedLocal(), RowSparseTransport(),
                     ServerUpdate("fedsubavg"))
    step = build_round_step(plan, _loss, params, _cfg(), telemetry=True)
    engine = jax.jit(lambda s, bs, ids: jax.lax.scan(
        lambda c, xs: step(c, *xs), s, (bs, ids)))
    cases = [_overflow_case(seed=60 + r) for r in range(n)]
    batches = jax.tree.map(lambda *xs: jnp.stack(xs), *[c[0] for c in cases])
    sub = jnp.stack([c[2] for c in cases])
    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    _, m = engine(state, batches, sub)
    events = split_rounds(m["telemetry"], n)
    for r in range(n):
        exp_dropped, exp_mass = _expected_drops(cases[r][1], 4)
        assert events[r]["dropped_ids"] == exp_dropped
        assert events[r]["dropped_mass"] == float(exp_mass)


@pytest.mark.skipif(NDEV < 2, reason="needs a multi-device mesh")
def test_overflow_exact_count_sharded():
    """The 8-forced-CPU-device path of the acceptance criteria: a sharded
    round reports the same exact drop count as the host-side truth."""
    params = _params()
    cfg = _cfg(k=NDEV)
    plan = RoundPlan(SubmodelReplicatedLocal(), RowSparseTransport(),
                     ServerUpdate("fedsubavg"),
                     sharding=CohortSharding(make_cohort_mesh()))
    step = jax.jit(build_round_step(plan, _loss, params, cfg, telemetry=True))
    state = ServerState(params, (), jnp.zeros((), jnp.int32))
    batch, feats, sub_small, sub_fit = _overflow_case(k=NDEV, seed=77)
    exp_dropped, exp_mass = _expected_drops(feats, 4)
    assert exp_dropped > 0

    _, m = step(state, batch, sub_small)
    tel = m["telemetry"]
    assert int(tel.dropped_ids) == exp_dropped
    assert float(tel.dropped_mass) == float(exp_mass)
    assert int(np.asarray(tel.dropped_per_client).sum()) == exp_dropped

    _, m2 = step(state, batch, sub_fit)
    assert int(m2["telemetry"].dropped_ids) == 0


def test_topk_compression_shrinks_post_norm():
    """delta_norm_pre/post bracket the top-k transport: post < pre when the
    transport drops rows, equal when it keeps everything."""
    params = _params()
    batch = _batch(41, (B * K, S))
    state = ServerState(params, (), jnp.zeros((), jnp.int32))

    def norms(topk):
        plan = RoundPlan(FedSgdLocal(), RowSparseTransport(topk=topk),
                         ServerUpdate("fedsubavg"))
        step = jax.jit(build_round_step(plan, _loss, params, _cfg(),
                                        telemetry=True))
        _, m = step(state, batch)
        t = m["telemetry"]
        return float(t.delta_norm_pre), float(t.delta_norm_post)

    pre, post = norms(topk=2)
    assert 0.0 < post < pre
    pre0, post0 = norms(topk=0)
    assert post0 == pytest.approx(pre0, rel=1e-6)


# ---------------------------------------------------------------------------
# trainer integration: compile split, sinks, verbose logging, profiler
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def ds():
    return make_movielens_like(num_clients=40, num_items=40, mean_samples=15)


def _trainer(ds, sink=None, telemetry=True, sparse=True, engine_cfg=None):
    cfg = engine_cfg or FedConfig(
        num_clients=ds.num_clients, clients_per_round=6, local_iters=3,
        local_batch=4, lr=0.5, algorithm="fedsubavg", sparse=sparse)
    return FederatedTrainer(ds, functools.partial(make_lr_params,
                                                  ds.num_features),
                            lr_loss, cfg, predict_fn=None, sink=sink,
                            telemetry=telemetry)


def test_trainer_compile_time_split(ds):
    """Satellite 1: the first chunk carries the jit compile, later chunks
    (and a whole second ``run``) report compile_time == 0; wall_time is the
    steady-state mean and no longer blends the compile in."""
    tr = _trainer(ds)
    tr.run(4, eval_every=2)
    assert tr.history[0].compile_time > 0
    assert tr.history[1].compile_time == 0.0
    assert 0 < tr.history[1].wall_time < tr.history[0].compile_time
    tr.run(4, eval_every=2)
    assert all(r.compile_time == 0.0 for r in tr.history[2:])


def test_trainer_telemetry_log_and_summary(ds):
    tr = _trainer(ds)
    tr.run(4, eval_every=2)
    assert len(tr.telemetry_log) == 4
    ev = tr.telemetry_log[0]
    for key in ("round", "dropped_ids", "dropped_mass", "union_size",
                "delta_norm_pre", "delta_norm_post", "heat_hist", "density",
                "comm"):
        assert key in ev
    s = tr.telemetry_summary()
    assert s["rounds"] == 4 and s["dropped_ids"] == 0
    assert s["mean_union_size"] > 0 and 0 < s["mean_density"] <= 1
    assert len(s["heat_hist"]) == HEAT_BUCKETS


def test_trainer_jsonl_sink(tmp_path, ds):
    path = tmp_path / "rounds.jsonl"
    tr = _trainer(ds, sink=TraceSink(str(path)))
    tr.run(4, eval_every=2)
    tr.sink.close()
    events = read_events(str(path))
    kinds = {e["event"] for e in events}
    assert kinds == {"round", "record"}
    rounds = [e for e in events if e["event"] == "round"]
    assert len(rounds) == 4
    assert "density" in rounds[0]["comm"]      # CommStats merged, un-collided
    records = [e for e in events if e["event"] == "record"]
    assert {"wall_time", "compile_time", "train_loss"} <= set(records[0])
    # everything on the wire is plain JSON scalars/lists
    json.dumps(events)


def test_trainer_parity_loop_and_engine(ds):
    """Acceptance parity at the trainer level: identical per-round losses
    with telemetry on/off, on both the per-round loop and the scan engine."""
    l_on = [_trainer(ds, telemetry=True).run_round() for _ in range(1)]
    t_on, t_off = _trainer(ds, telemetry=True), _trainer(ds, telemetry=False)
    assert [t_on.run_round() for _ in range(3)] == \
           [t_off.run_round() for _ in range(3)]
    e_on, e_off = _trainer(ds, telemetry=True), _trainer(ds, telemetry=False)
    assert e_on.run_rounds(3) == e_off.run_rounds(3)
    assert len(e_on.telemetry_log) == 3
    assert len(e_off.telemetry_log) == 0
    assert l_on  # loop path above produced a real loss


def test_trainer_dense_path_telemetry(ds):
    cfg = FedConfig(num_clients=ds.num_clients, clients_per_round=6,
                    local_iters=3, local_batch=4, lr=0.5,
                    algorithm="fedsubavg", sparse=False)
    tr = _trainer(ds, engine_cfg=cfg)
    tr.run(2, eval_every=2)
    assert len(tr.telemetry_log) == 2
    ev = tr.telemetry_log[0]
    assert ev["dropped_ids"] == 0 and ev["delta_norm_pre"] > 0


def test_trainer_verbose_reports_through_logging(ds, caplog):
    """Satellite 2: the verbose path goes through the logging reporter (the
    old print content preserved), capturable via caplog."""
    tr = _trainer(ds)
    with caplog.at_level(logging.INFO, logger="repro.telemetry"):
        tr.run(2, eval_every=2, verbose=True)
    msgs = [r.message for r in caplog.records]
    assert any("[fedsubavg] round 2:" in m and "loss=" in m for m in msgs)


def test_trainer_profile_dir_smoke(tmp_path, ds):
    """Acceptance: jax.profiler trace files land under profile_dir."""
    pdir = tmp_path / "prof"
    tr = _trainer(ds)
    tr.run(2, eval_every=2, profile_dir=str(pdir))
    files = glob.glob(os.path.join(str(pdir), "**", "*.xplane.pb"),
                      recursive=True)
    assert files, f"no profiler traces under {pdir}"
