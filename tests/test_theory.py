"""Theory reproduction: Example 1 / Figure 2 closed form, Theorems 1-2."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.preconditioner import (condition_number,
                                       measured_dispersion_bound,
                                       preconditioned_hessian)


def example1_fedavg(eta, n, rounds, w0=(1.0, 1.0)):
    """Closed form of §3.1: after r rounds w = diag(1-2eta/N, 1-2eta)^r w0."""
    w = np.array(w0, dtype=np.float64)
    hist = [w.copy()]
    for _ in range(rounds):
        w = np.array([(1 - 2 * eta / n) * w[0], (1 - 2 * eta) * w[1]])
        hist.append(w.copy())
    return np.array(hist)


def example1_fedsubavg(gamma, rounds, w0=(1.0, 1.0)):
    w = np.array(w0, dtype=np.float64)
    hist = [w.copy()]
    for _ in range(rounds):
        w = (1 - 2 * gamma) * w
        hist.append(w.copy())
    return np.array(hist)


def simulate_example1(algorithm: str, lr: float, n: int, rounds: int):
    """Simulate Example 1 with actual gradient updates + aggregation
    (full participation, exact gradients, I=1) and verify the closed form."""
    w = jnp.array([1.0, 1.0])
    counts = jnp.array([1.0, float(n)])        # w1 involves 1 client, w2 all
    hist = [np.array(w)]
    for _ in range(rounds):
        # client 1 grad: (2w1, 2w2); clients 2..N grad: (0, 2w2)
        g_sum = jnp.array([2 * w[0], 2 * n * w[1]])
        delta = -lr * g_sum / n                # FedAvg aggregation
        if algorithm == "fedsubavg":
            delta = delta * (n / counts)
        w = w + delta
        hist.append(np.array(w))
    return np.array(hist)


def test_example1_closed_form_fedavg():
    n, eta, r = 100, 0.5, 20
    sim = simulate_example1("fedavg", eta, n, r)
    closed = example1_fedavg(eta, n, r)
    np.testing.assert_allclose(sim, closed, rtol=1e-6)
    # the cold parameter w1 decays ~ (1-1/N)^r: painfully slow
    assert sim[-1][0] > 0.8
    assert abs(sim[-1][1]) < 1e-6


def test_example1_fedsubavg_converges_fast():
    n, gamma, r = 100, 0.5, 20
    sim = simulate_example1("fedsubavg", gamma, n, r)
    closed = example1_fedsubavg(gamma, r)
    np.testing.assert_allclose(sim, closed, atol=1e-7)
    assert np.abs(sim[-1]).max() < 1e-6        # both params at optimum


def _synthetic_quadratic_hessian(rng, n_clients=64, m=10, p_cold=0.1):
    """Each client i: f_i = ||x_{S(i)} - e_i||^2 -> H_i = 2 I_{S(i)}.
    Global H = (2/N) diag(n_m): exactly the paper's aligned-sum structure."""
    involved = rng.random((n_clients, m)) < np.linspace(p_cold, 1.0, m)
    involved[:, -1] = True
    involved[0] = True
    counts = involved.sum(axis=0).astype(np.float64)
    h = np.diag(2.0 * counts / n_clients)
    return h, counts, n_clients


def test_theorem1_ill_conditioning(rng):
    h, counts, n = _synthetic_quadratic_hessian(rng)
    kappa = condition_number(jnp.asarray(h))
    dispersion = measured_dispersion_bound(jnp.asarray(h), counts, rho2=2.0)
    # Theorem 1: kappa >= Theta(n_max/n_min); here exactly equal
    assert kappa == pytest.approx(dispersion, rel=1e-6)
    assert kappa > 5.0


def test_theorem2_preconditioning_flattens(rng):
    h, counts, n = _synthetic_quadratic_hessian(rng)
    h_hat = preconditioned_hessian(jnp.asarray(h), counts, float(n))
    kappa_hat = condition_number(h_hat)
    kappa = condition_number(jnp.asarray(h))
    # D^1/2 H D^1/2 = (2/N) D diag(n) = 2 I -> condition number 1
    assert kappa_hat == pytest.approx(1.0, rel=1e-5)
    assert kappa_hat < kappa


def test_theorem2_nondiagonal_case(rng):
    """With cross-terms the preconditioned kappa should still shrink."""
    h, counts, n = _synthetic_quadratic_hessian(rng)
    # add a small PSD perturbation that respects the involvement structure
    a = rng.normal(size=(h.shape[0], h.shape[0])) * 0.05
    h = h + a @ a.T * np.sqrt(np.outer(counts, counts)) / n
    kappa = condition_number(jnp.asarray(h))
    kappa_hat = condition_number(preconditioned_hessian(jnp.asarray(h), counts, float(n)))
    assert kappa_hat < kappa
